"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.query import compare_packed


def pack2bit_ref(codes_lanes: jnp.ndarray) -> jnp.ndarray:
    """(16, n_words) slot-major codes -> (n_words,) uint32 packed."""
    c = codes_lanes.astype(jnp.uint32)
    shifts = (30 - 2 * jnp.arange(16, dtype=jnp.uint32)).astype(jnp.uint32)
    return jnp.sum(c << shifts[:, None], axis=0, dtype=jnp.uint32)


def pattern_compare_ref(windows_t, patterns_t, plen, pos, *, n_real: int):
    """Oracle for pattern_scan: returns (lt, le, eq) int8 (B,)."""
    W, B = windows_t.shape
    win = windows_t.T                       # (B, W)
    patt = patterns_t.T
    # reuse the core compare, which operates on (B, W) windows directly
    # by faking a gather: compare_packed expects text+pos; instead inline
    # its word logic here against explicit windows.
    mask = _word_masks(plen, W)
    a = win & mask
    b = patt & mask
    eq_w = a == b
    prefix_eq = jnp.cumprod(eq_w.astype(jnp.int32), axis=-1)
    shifted = jnp.concatenate(
        [jnp.ones_like(prefix_eq[:, :1]), prefix_eq[:, :-1]], axis=-1)
    first_diff = (~eq_w) & (shifted == 1)
    lt_raw = jnp.any(first_diff & (a < b), axis=-1)
    eq_all = jnp.all(eq_w, axis=-1)
    truncated = pos + plen > n_real
    lt = lt_raw | (eq_all & truncated)
    eq = eq_all & ~truncated
    return (lt.astype(jnp.int8), (lt | eq).astype(jnp.int8),
            eq.astype(jnp.int8))


def _word_masks(plen, n_words):
    w = jnp.arange(n_words, dtype=jnp.int32)[None, :]
    r = jnp.clip(plen[:, None] - w * 16, 0, 16).astype(jnp.uint32)
    full = jnp.uint32(0xFFFFFFFF)
    return jnp.where(r == 0, jnp.uint32(0),
                     jnp.where(r == 16, full,
                               ~((jnp.uint32(1) << (32 - 2 * r)) - 1)))


def tier_scan_ref(patterns_t, plen, windows_t, sa, meta):
    """Oracle for tier_scan: dense (T, BQ, BR) compare + straddle masks.
    Shapes as in ``tier_scan_pallas``; returns four (T, BQ) int32."""
    T, W, BR = windows_t.shape
    BIG = jnp.int32(2**30)

    def one_tier(win_t, sa_t, meta_t):
        n_real, n_rows, offset, lo_b, hi_b = (meta_t[i] for i in range(5))
        mask = _word_masks(plen, W)                        # (BQ, W)
        a = win_t.T[None, :, :] & mask[:, None, :]         # (BQ, BR, W)
        b = patterns_t.T[:, None, :] & mask[:, None, :]
        eq_w = a == b
        prefix_eq = jnp.cumprod(eq_w.astype(jnp.int32), axis=-1)
        shifted = jnp.concatenate(
            [jnp.ones_like(prefix_eq[..., :1]), prefix_eq[..., :-1]], axis=-1)
        first_diff = (~eq_w) & (shifted == 1)
        lt = jnp.any(first_diff & (a < b), axis=-1)        # (BQ, BR)
        eq_all = jnp.all(eq_w, axis=-1)
        truncated = sa_t[None, :] + plen[:, None] > n_real
        eq = eq_all & ~truncated
        lt = lt | (eq_all & truncated)
        valid = jnp.arange(BR, dtype=jnp.int32)[None, :] < n_rows
        eq = eq & valid
        lt = lt & valid
        g = sa_t[None, :] + offset
        e = g + plen[:, None]
        owned = eq & (e > lo_b) & (e <= hi_b)
        return (jnp.sum(owned, axis=1).astype(jnp.int32),
                jnp.sum(lt, axis=1).astype(jnp.int32),
                jnp.sum(eq, axis=1).astype(jnp.int32),
                jnp.min(jnp.where(owned, g, BIG), axis=1))

    return jax.vmap(one_tier)(windows_t, sa.astype(jnp.int32), meta)


def tablet_scan_ref(patterns_t, plen, windows_t, pos, *, n_real: int):
    """Oracle for tablet_scan: dense (BQ, BR) compare then reductions."""
    W, BQ = patterns_t.shape
    _, BR = windows_t.shape
    mask = _word_masks(plen, W)                       # (BQ, W)
    a = windows_t.T[None, :, :] & mask[:, None, :]    # (BQ, BR, W)
    b = patterns_t.T[:, None, :] & mask[:, None, :]
    eq_w = a == b
    prefix_eq = jnp.cumprod(eq_w.astype(jnp.int32), axis=-1)
    shifted = jnp.concatenate(
        [jnp.ones_like(prefix_eq[..., :1]), prefix_eq[..., :-1]], axis=-1)
    first_diff = (~eq_w) & (shifted == 1)
    lt_raw = jnp.any(first_diff & (a < b), axis=-1)   # (BQ, BR)
    eq_all = jnp.all(eq_w, axis=-1)
    truncated = pos[None, :] + plen[:, None] > n_real
    eq = eq_all & ~truncated
    lt = lt_raw | (eq_all & truncated)
    rows = jnp.arange(BR, dtype=jnp.int32)[None, :]
    BIG = jnp.int32(2**30)
    first = jnp.min(jnp.where(eq, rows, BIG), axis=1)
    return (jnp.sum(eq, axis=1).astype(jnp.int32),
            jnp.sum(lt, axis=1).astype(jnp.int32),
            first)

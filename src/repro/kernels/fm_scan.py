"""FM-index backward-search kernels: rank/select over a compressed BWT.

The frozen storage tier (``repro.api.fm``) replaces the base suffix
array with a Burrows-Wheeler index — the move both follow-up papers
(arXiv 2007.10095, 2107.03341) make at genome scale.  ``count()``
becomes O(pattern_len) independent of text size: one backward-search
step per pattern symbol, each step two rank queries over the packed BWT.

Index layout (built host-side by ``repro.api.fm.FMIndex``):

* the BWT is taken over ``T$`` (virtual sentinel, ``$`` < all symbols),
  so its ``n + 1`` rows are the real suffix array plus one sentinel
  row.  Row ``i >= 1`` of ``SA$`` is row ``i - 1`` of the real SA, and
  the backward-search lower bound ``lo`` maps to ``first_rank = lo - 1``
  — bit-identical to the binary-search path, including ties (the base
  builder's shorter-suffix-first convention IS the sentinel order);
* DNA: 2-bit-packed words (``pack2bit`` layout), rank = blocked Occ
  checkpoint (every ``SB`` symbols) + an in-block popcount bit trick;
  the sentinel row stores dummy symbol 0 and rank subtracts it;
* tokens: uint8 BWT, per-symbol Occ checkpoints, compare-equal sums.

Per-step pattern symbols are pre-extracted into a dense ``(steps, B)``
plan (-1 = step inactive for that query), so the jnp oracle and the
Pallas kernel execute the identical schedule: the kernel's inner loop is
checkpoint gathers + popcounts, no per-query pattern indexing.
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

SB = 64                 # symbols per Occ checkpoint block
WPB = SB // 16          # packed words per block (DNA)
BLOCK_Q = 128           # queries per Pallas program
_EVEN = 0x55555555      # every 2-bit slot's low bit


@partial(jax.tree_util.register_dataclass,
         data_fields=("bwt", "occ", "cc", "marked", "marked_rank",
                      "samples", "sent_row", "n"),
         meta_fields=("is_dna", "sample_rate", "vocab"))
@dataclasses.dataclass(frozen=True)
class FMArrays:
    """Device view of one frozen table's FM-index (jit-friendly pytree).

    ``rows = n + 1`` BWT rows (row 0 is the ``$``-only suffix).  ``occ``
    holds exclusive prefix counts of the RAW symbol stream (the sentinel
    row's dummy 0 included — rank() subtracts it); ``cc[c]`` is
    ``C$[c] = 1 + #{symbols < c}``.  ``marked``/``marked_rank``/
    ``samples`` are the sampled-SA structures for locate(): row r is
    marked iff its text position ``SA$[r] % sample_rate == 0``, so every
    LF walk terminates within ``sample_rate`` steps."""
    bwt: jnp.ndarray          # DNA: (Wb,) uint32 packed | tokens: (Lp,) int32
    occ: jnp.ndarray          # (nblk + 1, vocab) int32 checkpoint counts
    cc: jnp.ndarray           # (vocab,) int32  C$ array
    marked: jnp.ndarray       # (Wm,) uint32 bitvector over rows
    marked_rank: jnp.ndarray  # (Wm,) int32 set bits before each word
    samples: jnp.ndarray      # (S,) int32 SA$ values of marked rows
    sent_row: jnp.ndarray     # () int32 row whose BWT symbol is $
    n: jnp.ndarray            # () int32 real text length (rows - 1)
    is_dna: bool
    sample_rate: int
    vocab: int


# ---------------------------------------------------------------------------
# rank — Occ(c, i) = occurrences of c in bwt$[0:i)
# ---------------------------------------------------------------------------
def _rank_packed(bwt, occ_flat, sent_row, c, i):
    """Vectorized packed-DNA rank: checkpoint gather + per-word popcount
    bit trick.  ``c``/``i`` int32 arrays of one shape."""
    blk = i // SB
    base = jnp.take(occ_flat, blk * 4 + c)
    rem = i - blk * SB
    pat = c.astype(jnp.uint32) * jnp.uint32(_EVEN)      # symbol repeated
    cnt = jnp.zeros_like(i)
    for j in range(WPB):
        w = jnp.take(bwt, blk * WPB + j)
        v = jnp.clip(rem - 16 * j, 0, 16)               # slots in range
        x = w ^ pat
        y = (~x) & ((~x) >> 1) & jnp.uint32(_EVEN)      # bit per match
        sh = (2 * (16 - jnp.clip(v, 1, 16))).astype(jnp.uint32)
        keep = jnp.where(v > 0, jnp.uint32(_EVEN) << sh, jnp.uint32(0))
        cnt = cnt + lax.population_count(y & keep).astype(jnp.int32)
    return base + cnt - ((c == 0) & (sent_row < i)).astype(jnp.int32)


def _rank_codes(bwt, occ_flat, sent_row, vocab, c, i):
    """Vectorized token rank: checkpoint gather + in-block compare-equal
    sum over the SB-symbol window."""
    blk = i // SB
    base = jnp.take(occ_flat, blk * vocab + c)
    rem = i - blk * SB
    offs = jnp.arange(SB, dtype=jnp.int32)
    vals = jnp.take(bwt, blk[..., None] * SB + offs)    # clips out of range
    hit = (vals == c[..., None]) & (offs < rem[..., None])
    cnt = jnp.sum(hit.astype(jnp.int32), axis=-1)
    return base + cnt - ((c == 0) & (sent_row < i)).astype(jnp.int32)


def rank(fa: FMArrays, c, i):
    """Occ(c, i) over the index — the rank primitive shared by backward
    search and LF walks (jnp oracle; the Pallas kernel inlines the
    packed variant)."""
    occ_flat = fa.occ.reshape(-1)
    if fa.is_dna:
        return _rank_packed(fa.bwt, occ_flat, fa.sent_row, c, i)
    return _rank_codes(fa.bwt, occ_flat, fa.sent_row, fa.vocab, c, i)


# ---------------------------------------------------------------------------
# per-step symbol plan
# ---------------------------------------------------------------------------
def syms_from_packed(patt: jnp.ndarray, plen: jnp.ndarray,
                     steps: int) -> jnp.ndarray:
    """(B, W) packed patterns -> (steps, B) int32 backward-order symbols
    (step t processes pattern position ``plen - 1 - t``; -1 = inactive)."""
    j = plen[None, :].astype(jnp.int32) - 1 - jnp.arange(
        steps, dtype=jnp.int32)[:, None]                   # (steps, B)
    valid = j >= 0
    jc = jnp.clip(j, 0, steps - 1)
    words = jnp.take_along_axis(patt, (jc // 16).T, axis=1).T
    sh = (30 - 2 * (jc % 16)).astype(jnp.uint32)
    sym = ((words >> sh) & jnp.uint32(3)).astype(jnp.int32)
    return jnp.where(valid, sym, -1)


def syms_from_codes(patt: jnp.ndarray, plen: jnp.ndarray,
                    steps: int) -> jnp.ndarray:
    """(B, L) code patterns -> (steps, B) int32 backward-order symbols."""
    j = plen[None, :].astype(jnp.int32) - 1 - jnp.arange(
        steps, dtype=jnp.int32)[:, None]
    valid = j >= 0
    jc = jnp.clip(j, 0, patt.shape[1] - 1)
    sym = jnp.take_along_axis(patt, jc.T, axis=1).T.astype(jnp.int32)
    return jnp.where(valid, sym, -1)


# ---------------------------------------------------------------------------
# backward search — jnp oracle (and the non-DNA production path)
# ---------------------------------------------------------------------------
def search_syms(fa: FMArrays, syms: jnp.ndarray):
    """Backward search over a (steps, B) symbol plan -> (lo, hi) int32
    rows of SA$: matches occupy rows [lo, hi), count = hi - lo,
    first_rank (real SA) = lo - 1."""
    B = syms.shape[1]
    rows = fa.n.astype(jnp.int32) + 1
    lo0 = jnp.zeros((B,), jnp.int32)
    hi0 = jnp.full((B,), 1, jnp.int32) * rows

    def body(t, carry):
        lo, hi = carry
        s = lax.dynamic_slice_in_dim(syms, t, 1, axis=0)[0]
        active = s >= 0
        known = s < fa.vocab            # symbol outside the text's alphabet
        sc = jnp.clip(s, 0, fa.vocab - 1)
        lo2 = jnp.take(fa.cc, sc) + rank(fa, sc, lo)
        hi2 = jnp.take(fa.cc, sc) + rank(fa, sc, hi)
        hi2 = jnp.where(known, hi2, lo2)                # unknown: empty run
        lo = jnp.where(active, lo2, lo)
        hi = jnp.where(active, hi2, hi)
        return lo, hi

    return lax.fori_loop(0, syms.shape[0], body, (lo0, hi0))


def backward_search(fa: FMArrays, patt, plen):
    """Count-path entry: encoded batch -> (lo, hi) SA$ rows."""
    if fa.is_dna:
        steps = patt.shape[1] * 16
        syms = syms_from_packed(patt, plen, steps)
    else:
        steps = patt.shape[1]
        syms = syms_from_codes(patt, plen, steps)
    return search_syms(fa, syms)


# ---------------------------------------------------------------------------
# LF walk — locate()'s device-side primitive (used for first_pos)
# ---------------------------------------------------------------------------
def _bwt_symbol(fa: FMArrays, r):
    if fa.is_dna:
        w = jnp.take(fa.bwt, r // 16)
        return ((w >> (30 - 2 * (r % 16)).astype(jnp.uint32))
                & jnp.uint32(3)).astype(jnp.int32)
    return jnp.take(fa.bwt, r).astype(jnp.int32)


def lf_walk(fa: FMArrays, rows):
    """Text positions of SA$ rows via sampled-SA LF walks, (B,) int32.
    Every walk stops within ``sample_rate`` steps (position 0 is always
    marked, so a walk never crosses the sentinel)."""
    r = jnp.asarray(rows, jnp.int32)

    def sample_pos(rr):
        w = jnp.take(fa.marked, rr // 32)
        lowmask = (jnp.uint32(1) << (rr % 32).astype(jnp.uint32)) - 1
        idx = (jnp.take(fa.marked_rank, rr // 32)
               + lax.population_count(w & lowmask).astype(jnp.int32))
        return jnp.take(fa.samples, idx)

    def body(_, carry):
        r, steps, pos, done = carry
        w = jnp.take(fa.marked, r // 32)
        hit = (((w >> (r % 32).astype(jnp.uint32)) & jnp.uint32(1)) != 0)
        stop = hit & ~done
        pos = jnp.where(stop, sample_pos(r) + steps, pos)
        done = done | stop
        s = _bwt_symbol(fa, r)
        r2 = jnp.take(fa.cc, s) + rank(fa, s, r)
        r = jnp.where(done, r, r2)
        steps = jnp.where(done, steps, steps + 1)
        return r, steps, pos, done

    init = (r, jnp.zeros_like(r), jnp.full_like(r, -1),
            jnp.zeros(r.shape, bool))
    _, _, pos, _ = lax.fori_loop(0, fa.sample_rate + 1, body, init)
    return pos


# ---------------------------------------------------------------------------
# Pallas kernel (packed DNA): the backward search as a blocked launch
# ---------------------------------------------------------------------------
def _fm_kernel(syms_ref, bwt_ref, occ_ref, meta_ref, lo_ref, hi_ref,
               *, steps: int):
    syms = syms_ref[...]                    # (steps, BLOCK_Q) int32
    bwt = bwt_ref[0]                        # (Wb,) uint32
    occ_flat = occ_ref[...].reshape(-1)     # (nblk1 * 4,) int32
    meta = meta_ref[0]                      # (8,) int32
    cc = meta[:4]
    sent = meta[4]
    rows = meta[5]
    B = syms.shape[1]
    lo0 = jnp.zeros((B,), jnp.int32)
    hi0 = jnp.full((B,), 1, jnp.int32) * rows

    def body(t, carry):
        lo, hi = carry
        s = lax.dynamic_slice_in_dim(syms, t, 1, axis=0)[0]
        active = s >= 0
        sc = jnp.clip(s, 0, 3)
        lo2 = jnp.take(cc, sc) + _rank_packed(bwt, occ_flat, sent, sc, lo)
        hi2 = jnp.take(cc, sc) + _rank_packed(bwt, occ_flat, sent, sc, hi)
        lo = jnp.where(active, lo2, lo)
        hi = jnp.where(active, hi2, hi)
        return lo, hi

    lo, hi = lax.fori_loop(0, steps, body, (lo0, hi0))
    lo_ref[0, :] = lo
    hi_ref[0, :] = hi


@functools.partial(jax.jit, static_argnames=("interpret",))
def fm_scan_pallas(syms: jnp.ndarray, bwt: jnp.ndarray, occ: jnp.ndarray,
                   meta: jnp.ndarray, *, interpret: bool = False):
    """syms: (steps, BQtot) int32 backward-order symbol plan (-1 =
    inactive; BQtot % BLOCK_Q == 0 — caller pads); bwt: (Wb,) uint32
    packed BWT; occ: (nblk + 1, 4) int32 checkpoints; meta: (8,) int32
    ``[C0..C3, sent_row, rows, 0, 0]``.  Returns (lo, hi) int32
    (BQtot,).  The whole index stays resident across the query grid —
    at 64 symbols/checkpoint a 1 Mbase BWT is ~0.6 MB."""
    steps, BQ = syms.shape
    assert BQ % BLOCK_Q == 0
    grid = (BQ // BLOCK_Q,)
    kernel = functools.partial(_fm_kernel, steps=steps)
    lo, hi = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((steps, BLOCK_Q), lambda q: (0, q)),
            pl.BlockSpec((1, bwt.shape[0]), lambda q: (0, 0)),
            pl.BlockSpec(occ.shape, lambda q: (0, 0)),
            pl.BlockSpec((1, 8), lambda q: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((1, BLOCK_Q), lambda q: (0, q))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, BQ), jnp.int32)] * 2,
        interpret=interpret,
    )(syms, bwt[None, :], occ, meta[None, :])
    return lo[0], hi[0]


def pallas_meta(fa: FMArrays) -> jnp.ndarray:
    """The (8,) int32 scalar block ``fm_scan_pallas`` wants."""
    meta = jnp.zeros((8,), jnp.int32)
    meta = meta.at[:4].set(fa.cc.astype(jnp.int32))
    meta = meta.at[4].set(fa.sent_row.astype(jnp.int32))
    meta = meta.at[5].set(fa.n.astype(jnp.int32) + 1)
    return meta


def finish_match(fa: FMArrays, lo, hi):
    """(lo, hi) -> (found, count, first_rank, first_pos) int32, matching
    the binary-search path's conventions exactly: ``first_rank`` is the
    real-SA lower-bound row ``lo - 1`` when found and -1 otherwise;
    ``first_pos`` is the matched run's first text position in suffix-rank
    order (one LF walk), -1 when not found."""
    count = hi - lo
    found = count > 0
    first_rank = jnp.where(found, lo - 1, -1)
    pos = lf_walk(fa, jnp.clip(lo, 1, fa.n))
    first_pos = jnp.where(found, pos, -1)
    return found, count.astype(jnp.int32), first_rank.astype(jnp.int32), \
        first_pos.astype(jnp.int32)

"""Pallas TPU kernel: blocked tablet range-scan (Accumulo seek+scan, §IV).

Compares a block of BQ patterns against a block of BR consecutive sorted
suffix rows in VMEM and accumulates, per pattern:
  count      — number of matching rows (occurrences),
  less       — rows strictly lexicographically before the pattern
               (summed over all row blocks this equals the lower bound),
  first_row  — minimum global row index among matches.

Grid is (query_blocks, row_blocks); row blocks iterate fastest, so the
outputs (indexed by query block only) are accumulated across row steps —
initialized at row step 0.  The (BQ, BR) compare tile lives in registers/
VMEM; the word loop carries a prefix-equality tile exactly like
pattern_scan but rank-2.

This kernel powers (a) the pure linear-scan query path (small tablets) and
(b) the hybrid path: binary-search to a row block, then one kernel step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128   # patterns per tile (sublane-major axis of the compare tile)
BLOCK_R = 256   # rows per tile (lane axis, 128-aligned)
BIG = 2**30     # "no match" sentinel for first_row


def _scan_kernel(patt_ref, plen_ref, win_ref, pos_ref,
                 count_ref, less_ref, first_ref,
                 *, n_real: int, n_words: int, n_rows: int):
    plen = plen_ref[...].reshape(-1, 1).astype(jnp.int32)   # (BQ, 1)
    pos = pos_ref[...].reshape(1, -1).astype(jnp.int32)     # (1, BR)

    bq = plen.shape[0]
    br = pos.shape[1]
    pe = jnp.ones((bq, br), jnp.bool_)
    lt = jnp.zeros((bq, br), jnp.bool_)
    for w in range(n_words):
        a = win_ref[w, :][None, :]                          # row word (1,BR)
        b = patt_ref[w, :][:, None]                         # pattern  (BQ,1)
        r = jnp.clip(plen - w * 16, 0, 16).astype(jnp.uint32)
        full = jnp.uint32(0xFFFFFFFF)
        mask = jnp.where(r == 0, jnp.uint32(0),
                         jnp.where(r == 16, full,
                                   ~((jnp.uint32(1) << (32 - 2 * r)) - 1)))
        am = a & mask                                       # (BQ, BR)
        bm = b & mask
        lt = lt | (pe & (am < bm))
        pe = pe & (am == bm)
    truncated = pos + plen > n_real                         # (BQ, BR)
    eq = pe & ~truncated
    lt = lt | (pe & truncated)

    row0 = pl.program_id(1) * br
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, br), 1)
    valid = rows < n_rows                                   # mask row padding
    eq = eq & valid
    lt = lt & valid
    first = jnp.min(jnp.where(eq, rows, jnp.int32(BIG)), axis=1)   # (BQ,)
    cnt = jnp.sum(eq.astype(jnp.int32), axis=1)
    less = jnp.sum(lt.astype(jnp.int32), axis=1)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        count_ref[...] = cnt[None, :]
        less_ref[...] = less[None, :]
        first_ref[...] = first[None, :]

    @pl.when(pl.program_id(1) != 0)
    def _acc():
        count_ref[...] += cnt[None, :]
        less_ref[...] += less[None, :]
        first_ref[...] = jnp.minimum(first_ref[...], first[None, :])


@functools.partial(jax.jit,
                   static_argnames=("n_real", "n_rows", "interpret"))
def tablet_scan_pallas(patterns_t: jnp.ndarray, plen: jnp.ndarray,
                       windows_t: jnp.ndarray, pos: jnp.ndarray,
                       *, n_real: int, n_rows: int | None = None,
                       interpret: bool = False):
    """patterns_t: (W, BQtot) uint32; plen: (BQtot,); windows_t: (W, BRtot)
    uint32 — packed windows of consecutive sorted rows; pos: (BRtot,) their
    text positions.  BQtot % BLOCK_Q == 0, BRtot % BLOCK_R == 0 (caller pads;
    pad queries with plen=0 rows match everything — strip after; pad rows
    with pos=n_real so they never match).  Returns (count, less, first_row)
    int32 (BQtot,)."""
    W, BQ = patterns_t.shape
    _, BR = windows_t.shape
    assert BQ % BLOCK_Q == 0 and BR % BLOCK_R == 0
    grid = (BQ // BLOCK_Q, BR // BLOCK_R)
    if n_rows is None:
        n_rows = BR
    kernel = functools.partial(_scan_kernel, n_real=n_real, n_words=W,
                               n_rows=n_rows)
    qvec = pl.BlockSpec((1, BLOCK_Q), lambda q, r: (0, q))
    count, less, first = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((W, BLOCK_Q), lambda q, r: (0, q)),
            qvec,
            pl.BlockSpec((W, BLOCK_R), lambda q, r: (0, r)),
            pl.BlockSpec((1, BLOCK_R), lambda q, r: (0, r)),
        ],
        out_specs=[qvec] * 3,
        out_shape=[jax.ShapeDtypeStruct((1, BQ), jnp.int32)] * 3,
        interpret=interpret,
    )(patterns_t, plen[None, :], windows_t, pos[None, :])
    return count[0], less[0], first[0]

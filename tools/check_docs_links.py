"""Docs integrity gate (stdlib only — CI runs it without PYTHONPATH).

Two failure modes, both of which have already happened to every docs
tree ever written:

* **dead links** — every relative markdown link in ``README.md`` and
  ``docs/*.md`` must point at a file that exists, and a ``#fragment``
  must match a real heading in the target (GitHub slug rules);
* **orphan pages** — every page under ``docs/`` must be reachable from
  ``docs/index.md`` by following links, else it silently rots.

Exit 0 when clean; exit 1 listing every violation.  Wired into the CI
lint job (docs/ci.md).

    python tools/check_docs_links.py
"""
from __future__ import annotations

import os
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: drop markdown/backticks, lowercase, strip
    punctuation, spaces to hyphens."""
    s = re.sub(r"[`*_]", "", heading.strip()).lower()
    s = re.sub(r"[^\w\- ]", "", s)
    return s.replace(" ", "-")


def anchors_of(path: str) -> set:
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    return {github_slug(h) for h in HEADING_RE.findall(text)}


def links_of(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        text = CODE_FENCE_RE.sub("", f.read())
    return LINK_RE.findall(text)


def main() -> int:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    pages = [os.path.join(root, "README.md")]
    docs_dir = os.path.join(root, "docs")
    pages += sorted(os.path.join(docs_dir, f)
                    for f in os.listdir(docs_dir) if f.endswith(".md"))
    errors = []
    graph = {}                       # abs page -> set of abs md targets

    for page in pages:
        rel_page = os.path.relpath(page, root)
        targets = set()
        for raw in links_of(page):
            if raw.startswith(EXTERNAL):
                continue
            target, _, frag = raw.partition("#")
            if not target:           # same-page #fragment
                if frag and github_slug(frag) not in anchors_of(page):
                    errors.append(f"{rel_page}: dead anchor '#{frag}'")
                continue
            dest = os.path.normpath(
                os.path.join(os.path.dirname(page), target))
            if not os.path.exists(dest):
                errors.append(f"{rel_page}: dead link '{raw}'")
                continue
            if dest.endswith(".md"):
                targets.add(dest)
                if frag and frag not in anchors_of(dest):
                    errors.append(f"{rel_page}: link '{raw}' — no such "
                                  f"heading in {os.path.relpath(dest, root)}")
        graph[page] = targets

    # reachability: BFS over md links from docs/index.md
    index = os.path.join(docs_dir, "index.md")
    if not os.path.exists(index):
        errors.append("docs/index.md is missing — nothing anchors the "
                      "docs map")
    else:
        seen, frontier = {index}, [index]
        while frontier:
            page = frontier.pop()
            for dest in graph.get(page, set()):
                if dest not in seen:
                    seen.add(dest)
                    frontier.append(dest)
        for page in pages:
            if page.startswith(docs_dir + os.sep) and page not in seen:
                errors.append(f"{os.path.relpath(page, root)}: "
                              f"unreachable from docs/index.md")

    n_links = sum(len(links_of(p)) for p in pages)
    if errors:
        print(f"docs link check FAILED ({len(errors)} problems, "
              f"{len(pages)} pages):")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"docs link check OK: {len(pages)} pages, {n_links} links, "
          f"all docs reachable from docs/index.md")
    return 0


if __name__ == "__main__":
    sys.exit(main())
